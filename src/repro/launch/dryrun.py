import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# This module is the ONLY place the 512-placeholder-device trick is used —
# tests and benches see the single real CPU device.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) this lowers + compiles the
appropriate step (train_step / prefill_step / serve_step) against
ShapeDtypeStruct inputs with production NamedShardings — no allocation.
``compiled.memory_analysis()`` proves the working set fits the chips;
``compiled.cost_analysis()`` + HLO collective parsing feed §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = "experiments/dryrun", save_hlo: bool = False,
            variant: str = "") -> dict:
    import jax
    from repro.configs.base import get_config
    from repro.configs.shapes import get_shape  # noqa: F401
    from repro.launch.mesh import make_production_mesh, n_chips
    from repro.launch.roofline import roofline_terms
    from repro.launch.steps import arch_for_shape, make_step_and_specs

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"

    t0 = time.time()
    step, args, in_sh, out_sh = make_step_and_specs(cfg, shape, mesh)
    # buffer donation, as production would run it: train updates
    # (params, opt) in place, serve updates the KV/state cache in place.
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    terms = roofline_terms(cost, hlo, chips, arch_for_shape(cfg, shape),
                           shape)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
            # authoritative: XLA's own peak over the buffer assignment
            "peak_bytes_per_device": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "roofline": terms,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{mesh_name}" + (f"_{variant}" if variant else "")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs.archs import ASSIGNED
    from repro.configs.shapes import SHAPES

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    failures = 0
    for arch, shape in combos:
        try:
            r = run_one(arch, shape, args.multi_pod, args.out,
                        args.save_hlo)
            rt = r["roofline"]
            print(f"OK  {arch:24s} {shape:12s} {r['mesh']:16s} "
                  f"compile={r['compile_s']:6.1f}s "
                  f"peak/dev={r['memory']['peak_bytes_per_device']/2**30:6.2f}GiB "
                  f"terms(c/m/coll)={rt['compute_s']:.2e}/{rt['memory_s']:.2e}/"
                  f"{rt['collective_s']:.2e}s dom={rt['dominant']}")
        except Exception as e:
            failures += 1
            print(f"FAIL {arch} {shape}: {type(e).__name__}: {e}")
            traceback.print_exc()
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
