"""Serving launcher — the ITFI flow on the serving engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced

Demonstrates the three-phase request path (DESIGN.md §2):
  1. prefill(batch_history)    — daily-job-cacheable state
  2. inject(fresh_events)      — the paper's inference-time injection
  3. decode                    — unchanged serving

and prints per-phase timings, showing injection costs O(suffix) rather
than O(history).

``--loop`` instead drives the **request-level Gateway** (feature stores
-> injector -> prefill-state cache -> engine behind the micro-batching
scheduler) with a deterministic seeded request trace: arrivals trickle
in one at a time (``gateway.submit``), feedback events ride along
between them (``gateway.observe``), panes flush on pane-full or
deadline (``gateway.tick``), and a per-request A/B split
(``--ab``: hash-assigned control/treatment arms as per-request
policies) shares the same panes. Served results are claimed off the
streaming surface (``gateway.poll``). Prints per-round throughput plus
the gateway's structured telemetry summary (paths, queue-delay
percentiles, cache stats):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
      --loop --users 500 --rounds 4 [--ab]

``--pool SLOTS`` swaps the host LRU for the paged device-resident
state pool (slot-table cache, one-hot gather/scatter pane assembly)
and ``--max-wait SECS`` turns on continuous batching (0 = serve every
arrival immediately in a padded partial pane — the latency floor):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
      --loop --pool 512 --max-wait 0 --users 500 --rounds 4

``--mesh data,model`` runs either mode **sharded**: the engine jits with
NamedSharding in/out specs over a ("data", "model") mesh and request
panes split over the data axis (``--batch`` must divide it). On CPU the
launcher reuses the dry-run's forced-host-device XLA trick so e.g.
``--mesh 8,1 --batch 16`` is runnable (and CI-testable) on one machine:

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
      --loop --mesh 8,1 --batch 16 --users 500 --rounds 4
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

# NOTE: jax is imported inside main(), after --mesh handling — forcing
# host devices for the CPU multi-device path must precede the first jax
# device query (same constraint as launch/dryrun.py).

DAY = 86400


def run_loop(cfg, params, args, mesh=None) -> None:
    """Deterministic seeded request trace through the Gateway:
    per-request arrivals interleaved with feedback events, pane-full and
    deadline flushes, optional per-request A/B arms."""
    from repro.core.ab import ARM_POLICIES, request_arm
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.scheduler import Gateway, ServerConfig

    n_users, n_items = args.users, cfg.vocab_size - 256
    feature_len = min(args.history, 64)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=args.batch, prefill_len=args.history,
        inject_len=args.fresh,
        cache_capacity=args.history + args.fresh + 64), mesh=mesh)
    rng = np.random.RandomState(args.seed)

    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=feature_len))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=n_users, buffer_len=16, ingest_latency=0))
    n_ev = n_users * 16
    us = rng.randint(0, n_users, n_ev)
    its = rng.randint(0, n_items, n_ev)
    tss = rng.randint(0, 5 * DAY, n_ev)
    store.extend(us, its, tss)
    rts.extend(us, its, tss)
    inj = FeatureInjector(InjectionConfig(
        policy=args.policy, feature_len=feature_len), store, rts)
    gw = Gateway(eng, inj, ServerConfig(
        slate_len=4, cache_entries=n_users,
        pool_slots=args.pool, max_wait=args.max_wait,
        snapshot_build_budget=args.build_budget,
        rewarm_budget=args.rewarm))
    if args.pool:
        print(f"paged state pool: {args.pool} device slots x "
              f"{gw.pool.slot_nbytes / 1e6:.2f} MB/slot"
              + (f", continuous max_wait={args.max_wait}s"
                 if args.max_wait is not None else ""))

    now = 5 * DAY + 100
    t0 = time.time()
    warmed = gw.warm(np.arange(n_users), now)
    print(f"warm: {warmed} prefill states in {time.time() - t0:.1f}s "
          f"(incl. compile)")

    deadline = args.batch * 2  # seconds an arrival may wait in the queue
    per_round = args.batch * 4
    for r in range(args.rounds):
        tickets = []
        t0 = time.time()
        for _ in range(per_round):
            # the trace interleaves arrivals with feedback events
            # (~1 event per 4 requests), all from one seeded stream
            if rng.rand() < 0.25:
                gw.observe((int(rng.randint(0, n_users)),
                            int(rng.randint(0, n_items)), now - 30))
            u = int(rng.randint(0, n_users))
            if args.ab:
                arm = request_arm(u, salt=args.seed)
                req = Request(user=u, now=now, policy=ARM_POLICIES[arm],
                              tag=arm, deadline=now + deadline)
            else:
                req = Request(user=u, now=now, deadline=now + deadline)
            tickets.append(gw.submit(req))
            now += 1  # one arrival per second
        served = gw.drain(now + deadline)  # tail deadline fires + claim
        dt = time.time() - t0
        assert all(t.done for t in tickets)
        assert {t.request_id for t in served} >= {t.request_id
                                                 for t in tickets}
        hits = sum(t.response.telemetry.cache_hit for t in tickets)
        qd = np.array([t.response.telemetry.queue_delay for t in tickets])
        print(f"round {r}: {len(tickets)} reqs in {dt * 1e3:6.1f}ms "
              f"({len(tickets) / dt:7.1f} req/s) hits={hits} "
              f"queue-delay p50={np.percentile(qd, 50):.0f}s "
              f"max={qd.max()}s slate[0]="
              f"{tickets[0].response.slate.tolist()}")
        # next round's arrivals must not be stamped behind the clock the
        # tail-flush tick just advanced to (now + deadline) — a backdated
        # arrival would inflate its queue-delay telemetry
        now += max(60, deadline)
        if args.roll_midway and r == args.rounds // 2 - 1:
            # jump the clock past the next daily boundary so the second
            # half of the trace serves across a generation rollover
            # (warm handoff; with --build-budget the build amortizes
            # over the ticks the serving rounds issue)
            now = ((now // DAY) + 1) * DAY + 100
            gw.tick(now)
            ro = gw.stats()["rollover"]
            print(f"-- generation rollover at {now}: rekeyed="
                  f"{ro['rekeyed']} invalidated={ro['invalidated']} "
                  f"pending_build={ro['pending_build_users']} "
                  f"pending_rewarm={ro['pending_rewarm']}")

    st = gw.stats()
    if args.ab:
        by_arm = {}
        for t in tickets:
            by_arm.setdefault(t.response.telemetry.tag, 0)
            by_arm[t.response.telemetry.tag] += 1
        print(f"last-round arms (mixed panes): {by_arm}")
    print(f"telemetry: paths={st['paths']} "
          f"queue_delay p50={st['queue_delay']['p50']:.0f}s "
          f"p99={st['queue_delay']['p99']:.0f}s "
          f"deadline_flushes={st['deadline_flushes']} "
          f"panes={st['panes']}")
    ro = st["rollover"]
    print(f"rollover: rollovers={ro['rollovers']} rekeyed={ro['rekeyed']} "
          f"invalidated={ro['invalidated']} rebuilt={ro['rebuilt']} "
          f"build_steps={ro['build_steps']} "
          f"build_time={ro['build_time_s']*1e3:.1f}ms")
    print(f"stats: {st.as_dict()}")


def run_scenario_cli(args) -> None:
    """--scenario NAME: replay one named production traffic scenario
    (serving/loadgen.py) against the chosen arch and print the SLO
    scorecard. mixed_fleet keeps its own multi-arch roster; every other
    scenario runs on a reduced variant of ``--arch``."""
    import dataclasses

    from repro.serving.loadgen import get_scenario, run_scenario

    spec = get_scenario(args.scenario, smoke=args.smoke)
    if args.arch and not spec.archs:
        spec = dataclasses.replace(spec, archs=(args.arch,))
    print(f"scenario {spec.name}: horizon={spec.horizon}s "
          f"users={spec.n_users} shed_policy={spec.shed_policy}")
    for res in run_scenario(spec):
        m = res.metrics
        print(f"\n[{res.arch}] trace={res.trace_fingerprint} "
              f"slates={res.slate_fingerprint}")
        print(f"  requests={m['requests']} served={m['served']} "
              f"shed={m['shed']} deadline_misses={m['deadline_misses']} "
              f"hit_rate={m['hit_rate']:.2f}")
        print(f"  queue delay p50/p99/max = {m['queue_delay']['p50']:.0f}/"
              f"{m['queue_delay']['p99']:.0f}/{m['queue_delay']['max']}s")
        for g in res.gates:
            mark = "PASS" if g["pass"] else "FAIL"
            print(f"  [{mark}] {g['gate']:22s} budget={g['budget']} "
                  f"actual={g['actual']}")
        print(f"  SLO: {'PASS' if res.slo_pass else 'FAIL'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="registered model config (required unless "
                         "--scenario, which defaults to its own tiny "
                         "ranker / fleet roster)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--history", type=int, default=256)
    ap.add_argument("--fresh", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loop", action="store_true",
                    help="drive the request-level Gateway with a seeded trace")
    ap.add_argument("--users", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--policy", default="inject",
                    choices=["batch", "inject", "fresh"])
    ap.add_argument("--ab", action="store_true",
                    help="--loop: per-request A/B arms (hash-assigned "
                         "control=batch / treatment=inject policies "
                         "sharing the same mixed-policy panes)")
    ap.add_argument("--roll-midway", action="store_true",
                    help="--loop: jump the clock past a daily boundary "
                         "halfway through the trace so the second half "
                         "serves across a generation rollover (warm "
                         "handoff)")
    ap.add_argument("--build-budget", type=int, default=None,
                    help="--loop: amortize snapshot builds — at most "
                         "this many users materialized per clock call "
                         "(default: synchronous full build)")
    ap.add_argument("--rewarm", type=int, default=0,
                    help="--loop: re-prefill up to this many "
                         "rollover-invalidated users per tick")
    ap.add_argument("--pool", type=int, default=None, metavar="SLOTS",
                    help="--loop: paged device-resident state pool with "
                         "this many slots (replaces the host LRU; must "
                         "be >= --batch)")
    ap.add_argument("--max-wait", type=int, default=None, metavar="SECS",
                    help="--loop: continuous batching — flush a partial "
                         "pane once its oldest arrival has waited this "
                         "long (0 = serve every arrival immediately)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="run sharded over a data,model mesh (e.g. 8,1); "
                         "--batch must be a multiple of the data size")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="replay a named production traffic scenario "
                         "(diurnal / flash_crowd / cold_start_storm / "
                         "churn_heavy / mixed_fleet) through the Gateway "
                         "against this --arch (reduced shapes) and print "
                         "the SLO scorecard; --smoke shrinks the trace")
    ap.add_argument("--smoke", action="store_true",
                    help="--scenario: short-horizon variant of the trace")
    args = ap.parse_args()

    if args.scenario:
        run_scenario_cli(args)
        return
    if args.arch is None:
        ap.error("--arch is required (except with --scenario)")

    mesh_shape = None
    if args.mesh:
        mesh_shape = tuple(int(x) for x in args.mesh.split(","))
        if len(mesh_shape) != 2:
            raise SystemExit("--mesh wants two sizes: data,model")
        n = mesh_shape[0] * mesh_shape[1]
        plat = os.environ.get("JAX_PLATFORMS", "")
        if n > 1 and (not plat or "cpu" in plat):
            # the dry-run trick: simulate the mesh's devices on one CPU
            # host (must land in XLA_FLAGS before jax first initializes)
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count={n}")

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import make_serving_mesh
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    mesh = None
    if mesh_shape is not None:
        mesh = make_serving_mesh(*mesh_shape)
        print(f"mesh: data={mesh_shape[0]} model={mesh_shape[1]} "
              f"({len(jax.devices())} devices visible)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed),
                         dtype=jnp.float32)

    if args.loop:
        run_loop(cfg, params, args, mesh=mesh)
        return

    scfg = ServingConfig(max_batch=args.batch, prefill_len=args.history,
                         inject_len=args.fresh,
                         cache_capacity=args.history + args.fresh + 64)
    eng = ServingEngine(cfg, params, scfg, mesh=mesh)
    rng = np.random.RandomState(args.seed)

    hists = [list(rng.randint(1, cfg.vocab_size, rng.randint(
        args.history // 2, args.history))) for _ in range(args.batch)]
    fresh = [list(rng.randint(1, cfg.vocab_size, rng.randint(1, args.fresh)))
             for _ in range(args.batch)]

    def timed(name, fn, *a):
        t0 = time.time()
        out = fn(*a)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        t1 = time.time()
        out2 = fn(*a)  # warm (jit-cached) call
        jax.block_until_ready(jax.tree.leaves(out2)[0])
        print(f"{name:22s} cold={t1 - t0:7.3f}s warm={time.time() - t1:7.3f}s")
        return out2

    toks, valid = eng.pad_tokens(hists, args.history)
    state = timed("prefill(batch hist)", eng.prefill, toks, valid)
    stoks, svalid = eng.pad_tokens(fresh, args.fresh, align="left")
    state = timed("inject(fresh events)", eng.inject, state, stoks, svalid)
    dec = timed("finalize(ring cache)", eng.finalize, state)

    tok = np.array([[1]] * args.batch, np.int32)
    t0 = time.time()
    for i in range(args.decode_steps):
        logits, dec = eng.decode(dec, tok)
        tok = np.asarray(eng.sample(logits))[:, None]
    jax.block_until_ready(logits)
    dt = (time.time() - t0) / args.decode_steps
    print(f"decode: {args.decode_steps} steps, {dt * 1e3:.1f} ms/step "
          f"(incl. first-step compile)")


if __name__ == "__main__":
    main()
