"""Quickstart: the paper's inference-time feature injection in 60 lines.

Builds the two feature stores, wires the injector, and shows a user whose
morning thriller binge changes their recommendations *within the day* —
without touching the batch-trained model (paper §III-B).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BatchFeatureStore, FeatureInjector, FeatureStoreConfig,
                        InjectionConfig, PipelineConfig, RecommenderPlatform,
                        RealtimeConfig, RealtimeFeatureService)
from repro.core.ab import default_sim_model
from repro.models.model import init_params

DAY = 86400
N_ITEMS = 500

# --- assemble the platform (one A/B arm) ------------------------------
model_cfg = default_sim_model(N_ITEMS)
params = init_params(model_cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

store = BatchFeatureStore(FeatureStoreConfig(n_users=2, feature_len=16))
rts = RealtimeFeatureService(RealtimeConfig(n_users=2, buffer_len=8,
                                            ingest_latency=30))

def make_arm(policy):
    inj = FeatureInjector(InjectionConfig(policy=policy, feature_len=16),
                          store, rts)
    pcfg = PipelineConfig(n_items=N_ITEMS, slate_size=5, serve_batch=2)
    pop = np.full((N_ITEMS,), 1.0 / N_ITEMS)
    return RecommenderPlatform(pcfg, model_cfg, params, inj, pop,
                               run_batch_jobs=False)

control = make_arm("batch")     # stale daily features (paper §III-A)
treatment = make_arm("inject")  # inference-time injection (paper §III-B)

# --- user 0 watched comedies yesterday --------------------------------
for ts, item in [(1000, 10), (2000, 11), (3000, 12)]:
    store.append(0, item, ts)
store.run_snapshot(DAY)  # the midnight batch job

# --- this morning they binged thrillers (items 400..402) ---------------
for i, item in enumerate([400, 401, 402]):
    rts.ingest(0, item, ts=DAY + 600 + i * 300)

# --- serve at noon ------------------------------------------------------
users, now = np.array([0]), np.array([DAY + 7200])
print("control   slate (stale batch features):", control.serve(users, now)[0])
print("treatment slate (injected fresh events):", treatment.serve(users, now)[0])
print("\nThe treatment arm merged", treatment.injector.realtime.events_ingested,
      "fresh events at inference time — zero model retraining.")
