"""End-to-end driver: train the sequential ranker on simulator logs.

Simulates a few days of long-form streaming traffic under a popularity
bootstrap policy, builds next-item training examples with the *batch*
(midnight) feature cutoff, and trains the ranker for a few hundred steps —
the "batch-trained model" every arm of the paper's experiment shares.

  PYTHONPATH=src python examples/train_ranker.py [--days 3] [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=3)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--users", type=int, default=400)
    ap.add_argument("--items", type=int, default=2000)
    ap.add_argument("--ckpt", default="/tmp/itfi_ranker.msgpack")
    args = ap.parse_args()

    from repro.core.ab import default_sim_model
    from repro.data.loader import LoaderConfig, batches, build_examples
    from repro.data.synthetic import (World, WorldConfig, bootstrap_serve_fn,
                                      events_to_arrays, simulate_day)
    from repro.models.model import init_params
    from repro.training.checkpoint import save_checkpoint
    from repro.training.optimizer import AdamWConfig, init_opt_state
    from repro.training.train_loop import TrainConfig, train

    wcfg = WorldConfig(n_users=args.users, n_items=args.items, seed=0)
    world = World(wcfg)
    serve = bootstrap_serve_fn(world, seed=0)
    events = []
    for day in range(args.days):
        evs, m = simulate_day(world, day, serve, lambda e: None, seed=0)
        events += evs
        print(f"day {day}: {len(evs)} events, ctr={m['ctr']:.3f}")

    lcfg = LoaderConfig(n_items=args.items, feature_len=48)
    ex = build_examples(events_to_arrays(events), lcfg, "midnight")
    print(f"{len(ex['labels'])} training examples (midnight cutoff)")

    cfg = default_sim_model(args.items)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params)
    nsteps = min(args.steps, len(ex["labels"]) // 128)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=20,
                                         total_steps=nsteps), remat=False)

    def limited():
        for i, b in enumerate(batches(ex, 128, epochs=10)):
            if i >= nsteps:
                return
            yield b

    out = train(cfg, tcfg, params, opt, limited(), log_every=25)
    save_checkpoint(args.ckpt, {"params": out["params"]},
                    step=nsteps, metadata={"arch": cfg.name})
    final = np.mean([h["acc"] for h in out["history"][-10:]])
    print(f"final next-item acc={final:.3f}; checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
