"""Serving-engine example: batched requests against an assigned arch.

Shows the TPU-native injection flow (prefill → inject → decode) on a
reduced mamba2 — the cheapest-injection family: fresh events advance an
O(1) recurrent state instead of growing a KV cache (DESIGN.md §4) —
then the same flow end to end through the request-level *Gateway*:
per-request submits with deadlines and per-request policies/slate
lengths, feedback events on the same facade, cache hits after warming,
and invalidation when the daily snapshot rolls.

  PYTHONPATH=src python examples/serve_injection.py [--arch mamba2-780m]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=args.batch, prefill_len=64, inject_len=8,
        cache_capacity=128))
    rng = np.random.RandomState(0)

    # a batch of users with different history lengths
    hists = [list(rng.randint(1, cfg.vocab_size, n)) for n in (60, 31, 7, 44)]
    toks, valid = eng.pad_tokens(hists, 64)
    state = eng.prefill(toks, valid)
    print(f"prefilled batch histories: lens={[len(h) for h in hists]}")

    # fresh intra-day events arrive for 3 of the 4 users
    fresh = [[5, 6], [9], [], [7, 8, 3]]
    stoks, svalid = eng.pad_tokens(fresh, 8, align="left")
    state = eng.inject(state, stoks, svalid)
    print(f"injected fresh events:     lens={[len(f) for f in fresh]}")

    dec = eng.finalize(state)
    tok = np.array([[1]] * args.batch, np.int32)
    outs = []
    for _ in range(8):
        logits, dec = eng.decode(dec, tok)
        tok = np.asarray(eng.sample(logits))[:, None]
        outs.append(tok[:, 0].tolist())
    print("greedy continuations (8 steps):")
    for row, (h, f) in enumerate(zip(hists, fresh)):
        print(f"  user {row}: hist={len(h):2d} fresh={len(f)} -> "
              f"{[o[row] for o in outs]}")

    # ------------------------------------------------------------------
    # The same flow end to end, request by request: the Gateway facade
    # (typed Request/Response lifecycle + micro-batching scheduler)
    # ------------------------------------------------------------------
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.serving.api import Event, Request
    from repro.serving.scheduler import Gateway, ServerConfig

    DAY = 86400
    n_users, n_items, feature_len = max(32, args.batch), cfg.vocab_size - 2, 32
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=feature_len))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=n_users, buffer_len=8, ingest_latency=0))
    n_ev = n_users * 12
    us = rng.randint(0, n_users, n_ev)
    its = rng.randint(0, n_items, n_ev)
    tss = rng.randint(0, 5 * DAY, n_ev)
    store.extend(us, its, tss)
    rts.extend(us, its, tss)
    gw = Gateway(
        eng,
        FeatureInjector(InjectionConfig(policy="inject",
                                        feature_len=feature_len), store, rts),
        ServerConfig(slate_len=4, cache_entries=n_users))

    now = 5 * DAY + 100
    print(f"\ngateway: warmed {gw.warm(np.arange(n_users), now)} "
          f"prefill states (daily-job precompute)")

    # requests trickle in one at a time; feedback events ride along on
    # the same facade; a full max_batch pane flushes automatically
    tickets = []
    for step, u in enumerate(range(args.batch)):
        gw.observe(Event(user=u, item=(u * 3) % n_items, ts=now + step - 10))
        # deadline past the last arrival, so the pane flushes on FULL
        tickets.append(gw.submit(Request(user=u, now=now + step,
                                         deadline=now + args.batch + 30)))
    t = tickets[0]
    tel = t.response.telemetry
    print(f"pane-full flush: {len(tickets)} arrivals -> pane {tel.pane_id}, "
          f"user {tel.user} path={tel.path!r} hit={tel.cache_hit} "
          f"queue_delay={tel.queue_delay}s slate={t.response.slate.tolist()}")

    # a short pane flushes when a deadline fires on the clock instead
    t1 = now + args.batch + 40
    late = gw.submit(Request(user=9, now=t1, deadline=t1 + 30,
                             slate_len=2))  # per-request slate length
    print(f"queued: pending={gw.pending} (pane not full, deadline not due)")
    gw.tick(t1 + 30)
    print(f"deadline flush:  user 9 served slate={late.response.slate.tolist()} "
          f"(slate_len=2) queue_delay={late.response.telemetry.queue_delay}s")

    # mixed-policy pane: the paper's A/B arms share one pane — the
    # per-request policy is the arm assignment
    now = t1 + 30
    arms = [gw.submit(Request(user=u, now=now + 100,
                              policy=("inject" if u % 2 else "batch"),
                              tag=("treatment" if u % 2 else "control")))
            for u in range(args.batch)]
    gw.flush()
    served = {a.response.telemetry.tag for a in arms}
    print(f"mixed-policy pane: arms {sorted(served)} served together "
          f"(pane {arms[0].response.telemetry.pane_id})")

    # next day: the snapshot generation rolls on the clock — a WARM
    # handoff, not a purge: users whose snapshot rows are unchanged
    # keep their cached states (rekeyed to the new generation), only
    # users with events in the rolled period re-prefill
    gw.tick(now + DAY)
    ro = gw.stats()["rollover"]
    print(f"next day: generation rolled — rekeyed={ro['rekeyed']} "
          f"invalidated={ro['invalidated']} (only changed users lose "
          f"their states)")
    r2 = [gw.submit(Request(user=u, now=now + DAY)) for u in range(8)]
    gw.flush()
    miss = sum(not t.response.telemetry.cache_hit for t in r2)
    print(f"first post-rollover pane: {miss}/8 misses (changed users "
          f"re-prefilled, the rest served from rekeyed states); "
          f"slates (first 3): {[t.response.slate.tolist() for t in r2[:3]]}")
    st = gw.stats()
    print(f"telemetry: paths={st['paths']} queue_delay_p99="
          f"{st['queue_delay']['p99']:.0f}s panes={st['panes']}")

    # ------------------------------------------------------------------
    # Continuous batching over the paged device-resident state pool:
    # prefill states live in preallocated device slots (no host round
    # trip per pane), max_wait=0 serves every arrival immediately in a
    # padded partial pane, and completions stream out through poll()
    # ------------------------------------------------------------------
    cgw = Gateway(
        eng,
        FeatureInjector(InjectionConfig(policy="inject",
                                        feature_len=feature_len), store, rts),
        ServerConfig(slate_len=4, pool_slots=max(16, 2 * args.batch),
                     max_wait=0))
    now = now + DAY + 200
    for step, u in enumerate(range(args.batch)):
        t = cgw.submit(Request(user=u, now=now + step))
        assert t.done  # continuous: served on arrival, no queueing
    done = cgw.poll()  # claim the stream of completions exactly once
    cst = cgw.stats()
    print(f"\ncontinuous+pooled: {len(done)} arrivals served in "
          f"{cst['panes']} partial panes, queue_delay_max="
          f"{cst['queue_delay']['max']}s, pool="
          f"{cst['cache']['slots']} slots "
          f"({cst['cache']['free_slots']} free), slates match the "
          f"wave path bitwise (tests/test_state_pool.py)")


if __name__ == "__main__":
    main()
