"""Serving-engine example: batched requests against an assigned arch.

Shows the TPU-native injection flow (prefill → inject → decode) on a
reduced mamba2 — the cheapest-injection family: fresh events advance an
O(1) recurrent state instead of growing a KV cache (DESIGN.md §4) —
then the same flow as the *end-to-end serving loop*: feature stores ->
FeatureInjector -> prefill-state cache -> engine, with cache hits after
warming and invalidation when the daily snapshot rolls.

  PYTHONPATH=src python examples/serve_injection.py [--arch mamba2-780m]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=args.batch, prefill_len=64, inject_len=8,
        cache_capacity=128))
    rng = np.random.RandomState(0)

    # a batch of users with different history lengths
    hists = [list(rng.randint(1, cfg.vocab_size, n)) for n in (60, 31, 7, 44)]
    toks, valid = eng.pad_tokens(hists, 64)
    state = eng.prefill(toks, valid)
    print(f"prefilled batch histories: lens={[len(h) for h in hists]}")

    # fresh intra-day events arrive for 3 of the 4 users
    fresh = [[5, 6], [9], [], [7, 8, 3]]
    stoks, svalid = eng.pad_tokens(fresh, 8, align="left")
    state = eng.inject(state, stoks, svalid)
    print(f"injected fresh events:     lens={[len(f) for f in fresh]}")

    dec = eng.finalize(state)
    tok = np.array([[1]] * args.batch, np.int32)
    outs = []
    for _ in range(8):
        logits, dec = eng.decode(dec, tok)
        tok = np.asarray(eng.sample(logits))[:, None]
        outs.append(tok[:, 0].tolist())
    print("greedy continuations (8 steps):")
    for row, (h, f) in enumerate(zip(hists, fresh)):
        print(f"  user {row}: hist={len(h):2d} fresh={len(f)} -> "
              f"{[o[row] for o in outs]}")

    # ------------------------------------------------------------------
    # The same flow end to end: stores -> injector -> cached serving loop
    # ------------------------------------------------------------------
    from repro.core.feature_store import BatchFeatureStore, FeatureStoreConfig
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.serving.loop import InjectionServer, ServerConfig

    DAY = 86400
    n_users, n_items, feature_len = 32, cfg.vocab_size - 2, 32
    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=feature_len))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=n_users, buffer_len=8, ingest_latency=0))
    n_ev = n_users * 12
    us = rng.randint(0, n_users, n_ev)
    its = rng.randint(0, n_items, n_ev)
    tss = rng.randint(0, 5 * DAY, n_ev)
    store.extend(us, its, tss)
    rts.extend(us, its, tss)
    srv = InjectionServer(
        eng,
        FeatureInjector(InjectionConfig(policy="inject",
                                        feature_len=feature_len), store, rts),
        ServerConfig(slate_len=4, cache_entries=n_users))

    now = 5 * DAY + 100
    print(f"\nserving loop: warmed {srv.warm(np.arange(n_users), now)} "
          f"prefill states (daily-job precompute)")
    users = np.arange(8)
    store.extend(users, (users * 3) % n_items, np.full(8, now - 10))
    rts.extend(users, (users * 3) % n_items, np.full(8, now - 10))
    res = srv.serve(users, now)
    print(f"request wave: hits={res.cache_hits} misses={res.cache_misses} "
          f"(fresh events injected, no re-prefill)")
    res2 = srv.serve(users, now + DAY)  # snapshot rolls -> invalidation
    print(f"next day:     hits={res2.cache_hits} misses={res2.cache_misses} "
          f"(generation rolled, states rebuilt)")
    print(f"slates (first 3 users): {res2.slate[:3].tolist()}")


if __name__ == "__main__":
    main()
