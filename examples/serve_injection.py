"""Serving-engine example: batched requests against an assigned arch.

Shows the TPU-native injection flow (prefill → inject → decode) on a
reduced mamba2 — the cheapest-injection family: fresh events advance an
O(1) recurrent state instead of growing a KV cache (DESIGN.md §4).

  PYTHONPATH=src python examples/serve_injection.py [--arch mamba2-780m]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    from repro.configs.base import get_config, reduced
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServingEngine(cfg, params, ServingConfig(
        max_batch=args.batch, prefill_len=64, inject_len=8,
        cache_capacity=128))
    rng = np.random.RandomState(0)

    # a batch of users with different history lengths
    hists = [list(rng.randint(1, cfg.vocab_size, n)) for n in (60, 31, 7, 44)]
    toks, valid = eng.pad_tokens(hists, 64)
    state = eng.prefill(toks, valid)
    print(f"prefilled batch histories: lens={[len(h) for h in hists]}")

    # fresh intra-day events arrive for 3 of the 4 users
    fresh = [[5, 6], [9], [], [7, 8, 3]]
    stoks, svalid = eng.pad_tokens(fresh, 8, align="left")
    state = eng.inject(state, stoks, svalid)
    print(f"injected fresh events:     lens={[len(f) for f in fresh]}")

    dec = eng.finalize(state)
    tok = np.array([[1]] * args.batch, np.int32)
    outs = []
    for _ in range(8):
        logits, dec = eng.decode(dec, tok)
        tok = np.asarray(eng.sample(logits))[:, None]
        outs.append(tok[:, 0].tolist())
    print("greedy continuations (8 steps):")
    for row, (h, f) in enumerate(zip(hists, fresh)):
        print(f"  user {row}: hist={len(h):2d} fresh={len(f)} -> "
              f"{[o[row] for o in outs]}")


if __name__ == "__main__":
    main()
