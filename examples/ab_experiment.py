"""The paper's §IV experiment, end to end (the headline reproduction).

Control (batch features, 24h stale) vs treatment (inference-time
injection) vs the consistent variant, with the feedback-loop training
pipeline and paired common-random-number days. Also runs the
feature-latency ablation when --latency is given.

  PYTHONPATH=src python examples/ab_experiment.py            # ~15 min
  PYTHONPATH=src python examples/ab_experiment.py --quick    # ~3 min
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--latency", action="store_true",
                    help="add feature-staleness ablation arms")
    ap.add_argument("--regime-b", action="store_true",
                    help="policy-confounded logs: positional trust bias + "
                         "scarce organic signal (tests the paper's "
                         "consistent-variant-null mechanism)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/ab_report.json")
    args = ap.parse_args()

    from repro.core.ab import ABConfig, run_experiment
    from repro.data.synthetic import WorldConfig

    wkw = dict(trust_bias=2.5, p_organic=0.10) if args.regime_b else {}
    if args.quick:
        ab = ABConfig(world=WorldConfig(n_users=200, n_items=1000,
                                        seed=args.seed, **wkw),
                      bootstrap_days=2, gen1_days=2, ab_days=3,
                      train_epochs=1, seed=args.seed)
    else:
        ab = ABConfig(world=WorldConfig(n_users=800, n_items=4000,
                                        sessions_per_day=2.0,
                                        seed=args.seed, **wkw),
                      seed=args.seed,
                      latency_arms=(86400, 21600, 3600, 60)
                      if args.latency else ())

    report = run_experiment(ab)

    print("\n================= ARMS =================")
    for name, a in report["arms"].items():
        print(f"{name:12s} ctr={a['ctr']:.4f} "
              f"({a['watches']}/{a['impressions']})")
    print("\n================= TESTS vs control =====")
    for name, t in report["tests"].items():
        print(f"{name:28s} lift={t['lift']*100:+.2f}% "
              f"CI=[{t['ci_lo']*100:+.2f}%, {t['ci_hi']*100:+.2f}%] "
              f"p={t['p_t']:.4f} {'SIGNIFICANT' if t['significant'] else 'ns'}")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"arms": report["arms"], "tests": report["tests"]}, f,
                  indent=1, default=str)
    print(f"\nreport -> {args.out}")


if __name__ == "__main__":
    main()
