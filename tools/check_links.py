"""Intra-repo markdown link checker (the CI docs job).

Walks every tracked-ish ``*.md`` in the repo, extracts inline links and
images ``[text](target)``, and fails when a *relative* target doesn't
exist on disk. External schemes (http/https/mailto), pure-anchor links
(``#section``), and targets that resolve outside the repo root (e.g. the
README's GitHub-web badge path ``../../actions/...``) are skipped — this
gate is about the repo's own docs tree staying internally consistent.

  python tools/check_links.py [root]

Exit 0 when every link resolves, 1 otherwise (each breakage listed).
"""
from __future__ import annotations

import os
import re
import sys

# [text](target) / ![alt](target), tolerating titles: (target "title")
_LINK = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_SCHEME = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules",
             ".ruff_cache", "experiments"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check(root: str):
    root = os.path.abspath(root)
    broken = []
    n_links = 0
    for path in sorted(md_files(root)):
        text = open(path, encoding="utf-8").read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if _SCHEME.match(target) or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]  # drop the fragment
            if not target:
                continue
            resolved = os.path.abspath(
                os.path.join(os.path.dirname(path), target))
            if not (resolved == root or
                    resolved.startswith(root + os.sep)):
                continue  # escapes the repo (GitHub-web paths like badges)
            n_links += 1
            if not os.path.exists(resolved):
                line = text[:m.start()].count("\n") + 1
                broken.append((os.path.relpath(path, root), line, target))
    return n_links, broken


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(os.path.dirname(__file__), "..")
    n_links, broken = check(root)
    for path, line, target in broken:
        print(f"BROKEN {path}:{line}: ({target})")
    print(f"checked {n_links} intra-repo links, {len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
