import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
# ^ MUST precede any jax import — the dry-run trick (launch/dryrun.py):
# jax locks the device count on first init. This script is run as a
# SUBPROCESS by tests/test_serving_sharded.py precisely so the forced
# device count never leaks into the main test process (conftest.py
# asserts it doesn't).

"""Sharded-vs-single-device serving equivalence check.

Builds the same tiny model + feature plane twice — one InjectionServer
on the plain single-device engine, one on an 8×1 ("data","model") CPU
mesh — and drives both through interleaved ingest/serve waves including
LRU-cached hits and a snapshot-generation rollover. Asserts slates are
IDENTICAL and logits agree within float tolerance at every wave.

  PYTHONPATH=src python tools/sharded_equiv_check.py

Prints ``SHARDED-EQUIV OK`` and exits 0 on success.
"""
import sys

import numpy as np


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.launch.mesh import make_serving_mesh
    from repro.models.model import init_params
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.loop import InjectionServer, ServerConfig

    assert len(jax.devices()) == 8, jax.devices()

    DAY = 86400
    n_users, n_items = 40, 300
    cfg = ModelConfig(name="equiv-test", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=n_items + 256, rope_theta=1e4,
                      tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    scfg = ServingConfig(max_batch=8, prefill_len=32, inject_len=8,
                         cache_capacity=64)

    def server(mesh):
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=24))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        rng = np.random.RandomState(0)
        u = rng.randint(0, n_users, 1500)
        i = rng.randint(0, n_items, 1500)
        t = rng.randint(0, 5 * DAY, 1500)
        store.extend(u, i, t)
        rts.extend(u, i, t)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=24), store, rts)
        eng = ServingEngine(cfg, params, scfg, mesh=mesh)
        return InjectionServer(eng, inj, ServerConfig(
            slate_len=3, cache_entries=64))

    single = server(mesh=None)
    sharded = server(mesh=make_serving_mesh(8, 1))

    rng = np.random.RandomState(1)
    now = 5 * DAY + 100
    # wave 1-3: interleaved ingest/serve inside one generation (misses,
    # then hits with fresh suffixes); wave 4: past the next snapshot
    # boundary — generation rollover purges and re-prefills
    for wave, at in enumerate([now, now + 120, now + 300,
                               now + DAY + 100]):
        u = rng.randint(0, n_users, 12)
        it = rng.randint(0, n_items, 12)
        ts = np.full(12, at - 40)
        for srv in (single, sharded):
            srv.injector.batch.extend(u, it, ts)
            srv.injector.realtime.extend(u, it, ts)
        q = rng.randint(0, n_users, 19)  # pane-splits at max_batch=8
        r1 = single.serve(q, at)
        r8 = sharded.serve(q, at)
        assert (r1.slate == r8.slate).all(), \
            f"wave {wave}: slates diverged\n{r1.slate}\n{r8.slate}"
        diff = np.abs(r1.scores - r8.scores).max()
        assert diff < 2e-3, f"wave {wave}: logits max|Δ|={diff}"
        print(f"wave {wave}: slates equal, logits max|Δ|={diff:.2e}, "
              f"hits={r8.cache_hits} misses={r8.cache_misses}")
    assert sharded.cache.hits > 0 and sharded.cache.invalidations > 0
    assert sharded.cache.shards == 8
    print("SHARDED-EQUIV OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
