import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
# ^ MUST precede any jax import — the dry-run trick (launch/dryrun.py):
# jax locks the device count on first init. This script is run as a
# SUBPROCESS by tests/test_serving_sharded.py precisely so the forced
# device count never leaks into the main test process (conftest.py
# asserts it doesn't).

"""Sharded-vs-single-device serving equivalence check.

Builds the same tiny model + feature plane twice — one request-level
Gateway on the plain single-device engine, one on an 8×1
("data","model") CPU mesh — and drives both through the same request
trace (per-request submits, interleaved ingest) including LRU-cached
hits, a mixed-policy wave (batch/inject/fresh rows sharing panes), and
TWO snapshot-generation rollovers: one crossed by a request's clock
mid-trace, one rolled explicitly by ``tick()`` between waves so the
warm handoff (rekeyed unchanged rows serving the next wave, changed
rows re-prefilled) is exercised and its telemetry compared across
meshes. Asserts slates are IDENTICAL and logits agree within float
tolerance at every wave.

  PYTHONPATH=src python tools/sharded_equiv_check.py

Prints ``SHARDED-EQUIV OK`` and exits 0 on success.
"""
import sys

import numpy as np


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.launch.mesh import make_serving_mesh
    from repro.models.model import init_params
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.scheduler import Gateway, ServerConfig

    assert len(jax.devices()) == 8, jax.devices()

    DAY = 86400
    n_users, n_items = 40, 300
    cfg = ModelConfig(name="equiv-test", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=n_items + 256, rope_theta=1e4,
                      tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    scfg = ServingConfig(max_batch=8, prefill_len=32, inject_len=8,
                         cache_capacity=64)

    def server(mesh):
        store = BatchFeatureStore(FeatureStoreConfig(
            n_users=n_users, feature_len=24))
        rts = RealtimeFeatureService(RealtimeConfig(
            n_users=n_users, buffer_len=8, ingest_latency=0))
        rng = np.random.RandomState(0)
        u = rng.randint(0, n_users, 1500)
        i = rng.randint(0, n_items, 1500)
        t = rng.randint(0, 5 * DAY, 1500)
        store.extend(u, i, t)
        rts.extend(u, i, t)
        inj = FeatureInjector(InjectionConfig(
            policy="inject", feature_len=24), store, rts)
        eng = ServingEngine(cfg, params, scfg, mesh=mesh)
        return Gateway(eng, inj, ServerConfig(
            slate_len=3, cache_entries=64))

    single = server(mesh=None)
    sharded = server(mesh=make_serving_mesh(8, 1))

    rng = np.random.RandomState(1)
    now = 5 * DAY + 100
    policies = [None, "batch", "inject", "fresh"]
    # wave 1-3: interleaved ingest/serve inside one generation (misses,
    # then hits with fresh suffixes; wave 3 mixes per-request policies
    # in shared panes); wave 4: past the next snapshot boundary — the
    # generation rolls mid-trace (warm handoff: unchanged rows rekey,
    # changed rows re-prefill); wave 5: an explicit mid-trace tick()
    # rolls ANOTHER generation with only a handful of changed users,
    # then the wave serves mostly from rekeyed entries
    for wave, at in enumerate([now, now + 120, now + 300,
                               now + DAY + 100, now + 2 * DAY + 100]):
        if wave == 4:
            # events for a FEW users only, then roll the generation on
            # the clock before any request arrives: the rollover itself
            # is the thing under test here
            u5 = np.arange(5)
            it5 = rng.randint(0, n_items, 5)
            for gw in (single, sharded):
                gw.observe_many(u5, it5, np.full(5, at - 3600))
                gw.tick(at - 60)
            r1 = single.stats()["rollover"]
            r8 = sharded.stats()["rollover"]
            assert r1 == r8, f"rollover stats diverged\n{r1}\n{r8}"
            # changed users' old-gen entries are RETAINED through the
            # handoff window (first-victim under pressure), not purged
            assert r8["rekeyed"] > 0 and r8["retained"] > 0, r8
            assert single.cache.rekeys == sharded.cache.rekeys > 0
            print(f"mid-trace rollover: rekeyed={r8['rekeyed']} "
                  f"retained={r8['retained']} (both meshes)")
        u = rng.randint(0, n_users, 12)
        it = rng.randint(0, n_items, 12)
        ts = np.full(12, at - 40)
        for gw in (single, sharded):
            gw.observe_many(u, it, ts)
        q = rng.randint(0, n_users, 19)  # pane-splits at max_batch=8
        reqs = [Request(user=int(x), now=at,
                        policy=policies[j % 4] if wave == 2 else None)
                for j, x in enumerate(q)]
        out = []
        for gw in (single, sharded):
            tickets = [gw.submit(r) for r in reqs]  # trickle: pane-full
            gw.flush(at)                            # flushes + tail
            out.append((np.stack([t.response.slate for t in tickets]),
                        np.stack([t.response.scores for t in tickets]),
                        sum(t.response.telemetry.cache_hit
                            for t in tickets)))
        (s1, l1, h1), (s8, l8, h8) = out
        assert (s1 == s8).all(), \
            f"wave {wave}: slates diverged\n{s1}\n{s8}"
        assert h1 == h8, f"wave {wave}: hit counts diverged {h1} != {h8}"
        diff = np.abs(l1 - l8).max()
        assert diff < 2e-3, f"wave {wave}: logits max|Δ|={diff}"
        print(f"wave {wave}: slates equal, logits max|Δ|={diff:.2e}, "
              f"hits={h8}")
    assert sharded.cache.hits > 0 and sharded.cache.invalidations > 0
    assert sharded.cache.shards == 8
    assert sharded.stats()["paths"]["inject"] > 0
    print("SHARDED-EQUIV OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
