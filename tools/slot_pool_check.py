import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
# ^ MUST precede any jax import (see tools/sharded_equiv_check.py). Run
# as a subprocess so the forced device count never leaks into the
# caller's process (conftest.py asserts it doesn't).

"""Slot-pool zero-collective check.

Builds a serving engine + paged state pool on an 8x1 ("data","model")
CPU mesh, compiles the pool's one-hot **gather** and **scatter**
programs, and scans their optimized HLO for collective ops
(all-reduce / all-gather / all-to-all / collective-permute /
reduce-scatter / collective-broadcast). The pool's slot axis is
replicated over the data axes precisely so these programs partition
with NO cross-device communication (sharding/rules.py
``slot_pool_pspecs``) — this script is the proof, re-run in CI next to
the bitwise sharded-equivalence check.

Also drives one pooled Gateway pane end-to-end on the mesh (admit ->
scatter -> gather -> inject -> decode) so the compiled programs it
scanned are the ones serving actually runs.

  PYTHONPATH=src python tools/slot_pool_check.py

Prints ``SLOT-POOL OK collectives=0`` and exits 0 on success.
"""
import re
import sys

import numpy as np

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|all-to-all|collective-permute|"
    r"reduce-scatter|collective-broadcast)\b")


def count_collectives(compiled) -> int:
    hlo = compiled.as_text()
    return len(COLLECTIVE_RE.findall(hlo))


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.core.feature_store import (BatchFeatureStore,
                                          FeatureStoreConfig)
    from repro.core.injection import FeatureInjector, InjectionConfig
    from repro.core.realtime import RealtimeConfig, RealtimeFeatureService
    from repro.launch.mesh import make_serving_mesh
    from repro.models.model import init_params
    from repro.serving.api import Request
    from repro.serving.engine import ServingConfig, ServingEngine
    from repro.serving.scheduler import Gateway, ServerConfig

    assert len(jax.devices()) == 8, jax.devices()

    DAY = 86400
    n_users, n_items = 40, 300
    cfg = ModelConfig(name="pool-check", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=n_items + 256, rope_theta=1e4,
                      tie_embeddings=True)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    scfg = ServingConfig(max_batch=8, prefill_len=32, inject_len=8,
                         cache_capacity=64)
    mesh = make_serving_mesh(8, 1)
    eng = ServingEngine(cfg, params, scfg, mesh=mesh)

    store = BatchFeatureStore(FeatureStoreConfig(
        n_users=n_users, feature_len=24))
    rts = RealtimeFeatureService(RealtimeConfig(
        n_users=n_users, buffer_len=8, ingest_latency=0))
    rng = np.random.RandomState(0)
    u = rng.randint(0, n_users, 1500)
    it = rng.randint(0, n_items, 1500)
    ts = rng.randint(0, 5 * DAY, 1500)
    store.extend(u, it, ts)
    rts.extend(u, it, ts)
    inj = FeatureInjector(InjectionConfig(policy="inject", feature_len=24),
                          store, rts)
    gw = Gateway(eng, inj, ServerConfig(slate_len=3, pool_slots=16,
                                        max_wait=0))
    pool = gw.pool

    # Serve a couple of continuous arrivals end-to-end first: this
    # populates/executes the exact jitted gather/scatter the pool owns.
    now = 5 * DAY + 100
    for j, user in enumerate([3, 7, 3, 11]):
        t = gw.submit(Request(user=user, now=now + j))
        assert t.done, t
    done = gw.poll()
    assert len(done) == 4 and gw.cache.hits >= 1, gw.cache.stats()
    assert pool.gathers == 4 and pool.scatters == 3, (pool.gathers,
                                                      pool.scatters)

    # Now lower + compile the same jit bodies with the pool's shardings
    # and scan the partitioned HLO for collectives.
    sds = lambda x: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                       sharding=a.sharding), x)
    oh = jax.ShapeDtypeStruct((scfg.max_batch, pool.n_slots), jnp.float32)
    gather_c = pool._gather.lower(
        sds(pool.caches), sds(pool.valid), sds(pool.next_pos),
        sds(pool.last_logits), oh).compile()
    p, vp = scfg.prefill_len, cfg.vocab_padded
    st_logits = jax.ShapeDtypeStruct((scfg.max_batch, p, vp), jnp.float32)
    scatter_c = pool._scatter.lower(
        sds(pool.caches), sds(pool.valid), sds(pool.next_pos),
        sds(pool.last_logits),
        jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                (a.shape[0], scfg.max_batch) + a.shape[2:], a.dtype),
            pool.caches),
        jax.ShapeDtypeStruct((scfg.max_batch, p), jnp.bool_),
        jax.ShapeDtypeStruct((scfg.max_batch,), jnp.int32),
        st_logits, oh).compile()

    ng = count_collectives(gather_c)
    ns = count_collectives(scatter_c)
    print(f"gather: collectives={ng}  scatter: collectives={ns} "
          f"(8-way data mesh, {pool.n_slots} slots)")
    assert ng == 0, f"slot gather compiled with {ng} collectives"
    assert ns == 0, f"slot scatter compiled with {ns} collectives"
    print(f"SLOT-POOL OK collectives={ng + ns}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
