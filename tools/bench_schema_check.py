"""Validate every committed BENCH_*.json against its declared schema.

    python tools/bench_schema_check.py [dir]

Each bench suite writes a JSON artifact; nothing until now checked that
those files keep the shape the docs (docs/benchmarks.md) and downstream
readers rely on — a refactor could silently rename a key and the
committed artifact would drift from its schema without any signal. This
tool pins the contract: a minimal declarative schema per suite (required
keys + types; extra keys are allowed, artifacts are free to carry more
detail than the schema pins), plus suite-specific semantic checks (the
scenarios artifact must record a reproduced determinism replay, and
every scenario row's ``slo_pass`` must agree with its own gate list).

Stdlib only; exits non-zero on the first schema violation so CI fails
loudly. Run over the repo root it validates every committed artifact.
"""
from __future__ import annotations

import glob
import json
import os
import sys

# ---------------------------------------------------------------------
# schema mini-language: a spec is a type, a tuple of types, a dict of
# key -> spec (required keys; unlisted keys pass through), or a
# one-element list [spec] (homogeneous list, every element checked)
# ---------------------------------------------------------------------

NUM = (int, float)


def check(value, spec, path):
    if isinstance(spec, dict):
        if not isinstance(value, dict):
            return [f"{path}: expected object, got {type(value).__name__}"]
        errs = []
        for key, sub in spec.items():
            if key not in value:
                errs.append(f"{path}.{key}: missing required key")
            else:
                errs.extend(check(value[key], sub, f"{path}.{key}"))
        return errs
    if isinstance(spec, list):
        if not isinstance(value, list):
            return [f"{path}: expected array, got {type(value).__name__}"]
        errs = []
        for i, item in enumerate(value):
            errs.extend(check(item, spec[0], f"{path}[{i}]"))
        return errs
    if spec is None:  # any type accepted (value may also be null)
        return []
    if isinstance(value, bool) and spec in (int, NUM):
        return [f"{path}: expected number, got bool"]
    if not isinstance(value, spec):
        want = getattr(spec, "__name__", "/".join(
            t.__name__ for t in spec))
        return [f"{path}: expected {want}, got {type(value).__name__}"]
    return []


_CONFIG = {"arch": str, "max_batch": int, "prefill_len": int,
           "inject_len": int, "feature_len": int, "slate_len": int}

SCHEMAS = {
    "feature_plane": {"suite": str, "smoke": bool, "results": [dict]},
    "serving": {"suite": str, "smoke": bool, "config": _CONFIG,
                "results": [dict]},
    "serving_sharded": {
        "suite": str, "smoke": bool, "config": _CONFIG,
        "results": {"meshes": [dict], "equivalence": dict,
                    "rps_scaling_1_to_8": NUM}},
    "scheduler": {
        "suite": str, "smoke": bool,
        "config": dict(_CONFIG, deadline_s=int),
        "slot_pool_check": {"ok": bool, "collectives": int},
        "results": [dict]},
    "rollover": {
        "suite": str, "smoke": bool, "config": _CONFIG,
        "results": {
            "build": {
                "n_users": int, "changed_users": int,
                "full_build_s": NUM, "incremental_total_s": NUM,
                "incremental_max_clock_slice_s": NUM,
                "bitwise_equal_oracle": bool,
                # the off-thread builder row: serving-thread slices only
                "background": {
                    "create_s": NUM, "wall_total_s": NUM,
                    "serving_thread_busy_s": NUM, "polls": int,
                    "max_clock_slice_s": NUM, "worker_steps": int,
                    "bitwise_equal_oracle": bool,
                    "stall_reduction": NUM}},
            "serving": {
                "modes": {"eager": dict, "warm": dict,
                          "background": dict},
                "responses_bitwise_equal": bool}}},
    "online": {
        "suite": str, "smoke": bool, "config": _CONFIG,
        "results": {
            "cadence": [{
                "name": str, "install_every_waves": int, "policy": str,
                "patches_applied": int, "model_version": int,
                "rps": NUM, "hit_rate": NUM,
                "patch_install_max_ms": NUM,
                "patch_install_mean_ms": NUM}],
            "swap": {"bitwise_equal": bool, "patches_applied": int,
                     "model_version": int, "install_ms": NUM,
                     "patch_leaves": int, "patch_params": int},
            "drift": {"chunks": int, "drift_chunk": int,
                      "online_loss": [NUM], "frozen_loss": [NUM],
                      "online_post_drift_loss": NUM,
                      "frozen_post_drift_loss": NUM,
                      "adaptation_ratio": NUM}}},
    "ingest": {
        "suite": str, "smoke": bool,
        "config": {"window": int, "retention_windows": int,
                   "segment_k": int, "hot_budget": int,
                   "events_per_window": int, "rollovers": int},
        "results": {
            "bounded": {
                "rollovers": int, "events": int,
                "bytes_total_per_rollover": [int],
                "unbounded_bytes": int,
                "bytes_ratio_vs_unbounded": NUM,
                "ingest_rate_events_per_s": NUM,
                "steady_state_bounded": bool, "counters": dict},
            "oracle": {"events": int, "late_events": int, "demoted": int,
                       "compactions": int, "queries": int,
                       "oracle_bitwise": bool},
            "churn_compact": {
                "slo_pass": bool, "deterministic": bool,
                "decay_requests": int, "compactions": int,
                "trace_fingerprint": str, "slate_fingerprints": [str],
                "metrics": dict, "ingest": dict,
                "gates": [{"gate": str, "budget": None, "actual": None,
                           "pass": bool}]}}},
    "scenarios": {
        "suite": str, "smoke": bool,
        "config": {"scenarios": [str]},
        "determinism": {"scenario": str, "trace_fingerprints": [str],
                        "slate_fingerprints": [str],
                        "reproducible": bool},
        "results": [{
            "name": str, "arch": None, "trace_fingerprint": str,
            "slate_fingerprint": str, "slo_pass": bool,
            "slo": dict, "gateway_stats": dict,
            "metrics": {
                "requests": int, "served": int, "shed": int,
                "shed_rate": NUM, "deadline_misses": int,
                "deadline_miss_rate": NUM, "hit_rate": NUM,
                "queue_delay": {"p50": NUM, "p99": NUM, "max": int},
                "wall_ms_p99": dict, "paths": dict},
            "gates": [{"gate": str, "budget": None, "actual": None,
                       "pass": bool}],
        }]},
}


def semantic_checks(doc, path):
    """Suite-specific invariants beyond key shapes."""
    errs = []
    if doc.get("suite") == "rollover":
        res = doc.get("results", {})
        for key, row in (("build", res.get("build", {})),
                         ("build.background",
                          res.get("build", {}).get("background", {}))):
            if row.get("bitwise_equal_oracle") is not True:
                errs.append(f"{path}.results.{key}: build not certified "
                            f"bitwise equal to the full-rebuild oracle")
        if res.get("serving", {}).get("responses_bitwise_equal") is not True:
            errs.append(f"{path}.results.serving: modes did not serve "
                        f"bitwise-identical responses")
    if doc.get("suite") == "online":
        res = doc.get("results", {})
        swap = res.get("swap", {})
        if swap.get("bitwise_equal") is not True:
            errs.append(f"{path}.results.swap: hot-swapped responses not "
                        f"certified bitwise equal to a cold gateway from "
                        f"the patched weights")
        for i, row in enumerate(res.get("cadence", [])):
            # a patch that "installed" without advancing the served
            # model version is the silent-corruption case the
            # base_version guard exists to prevent
            if row.get("patches_applied", 0) >= 1 and \
                    row.get("model_version", 0) < 1:
                errs.append(f"{path}.results.cadence[{i}] "
                            f"({row.get('name')}): patches_applied="
                            f"{row.get('patches_applied')} but "
                            f"model_version never advanced")
            # the hot-swap is O(patch) BETWEEN panes: the worst single
            # serving-thread install slice must stay tiny. Wall-clock,
            # so gated on the committed full-size artifact only — a
            # smoke regeneration on an arbitrary CI host measures the
            # host, not the code
            if not doc.get("smoke") and \
                    row.get("patch_install_max_ms", 0.0) > 5.0:
                errs.append(f"{path}.results.cadence[{i}] "
                            f"({row.get('name')}): install stall "
                            f"{row.get('patch_install_max_ms'):.2f}ms "
                            f"exceeds the 5ms budget")
        drift = res.get("drift", {})
        if drift.get("adaptation_ratio", 0.0) < 1.0:
            errs.append(f"{path}.results.drift: online post-drift loss "
                        f"not below the frozen model's")
    if doc.get("suite") == "ingest":
        res = doc.get("results", {})
        bnd = res.get("bounded", {})
        if bnd.get("steady_state_bounded") is not True:
            errs.append(f"{path}.results.bounded: sustained ingest not "
                        f"certified memory-bounded")
        samples = bnd.get("bytes_total_per_rollover", [])
        ret = bnd.get("counters", {}).get("retention_windows", 0)
        tail = samples[ret:]
        # re-derive the in-suite gate from the recorded series: the
        # artifact cannot claim boundedness its own numbers contradict
        if len(tail) < 3:
            errs.append(f"{path}.results.bounded: fewer than 3 "
                        f"steady-state rollovers recorded")
        elif all(b > a for a, b in zip(tail, tail[1:])):
            errs.append(f"{path}.results.bounded: recorded footprint "
                        f"grew monotonically in steady state: {tail}")
        if res.get("oracle", {}).get("oracle_bitwise") is not True:
            errs.append(f"{path}.results.oracle: tiered log not certified "
                        f"bitwise equal to the unbounded oracle")
        cc = res.get("churn_compact", {})
        if cc.get("slo_pass") is not True:
            errs.append(f"{path}.results.churn_compact: scenario failed "
                        f"its SLO contract with compaction live")
        if bool(cc.get("slo_pass")) != all(g.get("pass")
                                           for g in cc.get("gates", [])):
            errs.append(f"{path}.results.churn_compact: slo_pass "
                        f"disagrees with its gate list")
        if cc.get("deterministic") is not True:
            errs.append(f"{path}.results.churn_compact: replay did not "
                        f"reproduce identical slates")
        if cc.get("compactions", 0) < 3:
            errs.append(f"{path}.results.churn_compact: fewer than 3 "
                        f"compactions ran during the trace")
        if cc.get("decay_requests", 0) < 1:
            errs.append(f"{path}.results.churn_compact: no decay-arm "
                        f"rows served in the mixed panes")
    if doc.get("suite") == "scenarios":
        det = doc.get("determinism", {})
        if det.get("reproducible") is not True:
            errs.append(f"{path}: determinism replay did not reproduce")
        for i, row in enumerate(doc.get("results", [])):
            gates_ok = all(g.get("pass") for g in row.get("gates", []))
            if bool(row.get("slo_pass")) != gates_ok:
                errs.append(f"{path}.results[{i}] ({row.get('name')}): "
                            f"slo_pass={row.get('slo_pass')} disagrees "
                            f"with its gate list")
            m = row.get("metrics", {})
            if m.get("served", 0) + m.get("shed", 0) != m.get("requests"):
                errs.append(f"{path}.results[{i}] ({row.get('name')}): "
                            f"served + shed != requests")
    return errs


def validate_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    suite = doc.get("suite")
    if suite not in SCHEMAS:
        return [f"{path}: unknown suite {suite!r} "
                f"(declared schemas: {sorted(SCHEMAS)})"]
    errs = check(doc, SCHEMAS[suite], path)
    errs.extend(semantic_checks(doc, path))
    return errs


def main(argv):
    root = argv[1] if len(argv) > 1 else "."
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        print(f"no BENCH_*.json under {os.path.abspath(root)}")
        return 1
    failures = 0
    for p in paths:
        errs = validate_file(p)
        if errs:
            failures += 1
            print(f"FAIL {p}")
            for e in errs:
                print(f"  {e}")
        else:
            print(f"ok   {p}")
    if failures:
        print(f"{failures} of {len(paths)} artifacts failed schema check")
        return 1
    print(f"all {len(paths)} artifacts conform to their declared schemas")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
